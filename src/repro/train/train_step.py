"""Train / serve step builders: model + sync strategy + optimizer, sharded.

The step is built once per (arch, shape, mesh, strategy) cell.  Model
compute always runs under GSPMD (``jax.jit`` + sharding constraints): FSDP
over ``data`` and tensor parallelism over ``model`` inside a pod.  The pod
(WAN-analogue) boundary is owned by the GeoCoCo communicator: the gradient
exchange runs in a fully-manual ``shard_map`` over the whole mesh, where
``repro.dist.collectives.sync_gradients`` resolves the configured strategy
through the two-plane registry.  This split — GSPMD inside the pod, an
explicit collective program across pods — mirrors the paper's architecture
(intra-group transfers are cheap and automatic; the inter-group exchange is
planned) and is also the only layering XLA's CPU partitioner executes
reliably (partial-auto manual regions CHECK-fail; see
``repro.dist.compat``).

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input, so
the multi-pod dry-run lowers and compiles with zero allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeSpec
from ..dist import compat
from ..dist.collectives import SyncConfig, sync_gradients
from ..dist.sharding import param_shardings, param_specs
from ..models.model import forward, init_cache, init_params
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update

__all__ = [
    "TrainConfig",
    "input_specs",
    "abstract_params",
    "abstract_opt_state",
    "abstract_residuals",
    "abstract_cache",
    "build_train_step",
    "build_serve_step",
    "loss_fn",
]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    sync: SyncConfig = SyncConfig()
    optim: AdamWConfig = AdamWConfig()
    param_dtype: Any = jnp.float32      # bf16 for the lean 671B policy
    compute_dtype: Any = jnp.bfloat16
    # gradient-accumulation microbatches: activation memory scales ~1/m and
    # gradients sync once per step (GeoCoCo semantics unchanged)
    microbatches: int = 1


# ---------------------------------------------------------------------------
# abstract inputs (dry-run stand-ins)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for the model inputs of one cell."""
    gb, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        toks = {"tokens": jax.ShapeDtypeStruct((gb, 1), jnp.int32)}
        if cfg.n_img_tokens:
            toks["img"] = jax.ShapeDtypeStruct(
                (gb, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16
            )
        return toks
    batch: dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.frontend == "token":
        batch["tokens"] = jax.ShapeDtypeStruct((gb, s), jnp.int32)
    else:
        batch["embeds"] = jax.ShapeDtypeStruct((gb, s, cfg.d_model), jnp.bfloat16)
    if cfg.n_img_tokens:
        batch["img"] = jax.ShapeDtypeStruct(
            (gb, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16
        )
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((gb, s), jnp.int32)
    return batch


def abstract_params(cfg: ModelConfig, dtype=jnp.float32):
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, dtype), shapes)


def abstract_opt_state(cfg: ModelConfig, tcfg: TrainConfig):
    params = abstract_params(cfg, tcfg.param_dtype)
    return jax.eval_shape(lambda p: adamw_init(p, tcfg.optim), params)


def abstract_residuals(cfg: ModelConfig, tcfg: TrainConfig):
    if not tcfg.sync.needs_residuals:
        return None
    params = abstract_params(cfg, tcfg.param_dtype)
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32), params)


def abstract_cache(cfg: ModelConfig, shape: ShapeSpec, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len, dtype)
    )


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------


def _fit_batch_axes(mesh: Mesh, dim: int) -> tuple[str, ...]:
    """Largest prefix-combination of (pod, data) that divides ``dim``.

    A global_batch of 1 (long_500k single-request decode) replicates over the
    batch axes; the model axis still shards the compute."""
    cands = [("pod", "data"), ("data",), ("pod",)]
    for axes in cands:
        if all(a in mesh.shape for a in axes):
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if size > 1 and dim % size == 0:
                return axes
    return ()


def _batch_shardings(batch_tree, mesh: Mesh):
    def one(l):
        if getattr(l, "ndim", 0) == 0:
            return NamedSharding(mesh, P())
        axes = _fit_batch_axes(mesh, l.shape[0])
        return NamedSharding(mesh, P(axes or None, *([None] * (l.ndim - 1))))

    return jax.tree.map(one, batch_tree)


def _is_scan_path(path) -> bool:
    for p in path:
        if getattr(p, "key", None) == "scan":
            return True
    return False


def _cache_shardings(cache_tree, mesh: Mesh):
    """Decode-cache shardings.  Leaves under the "scan" key are stacked with
    a leading super-block axis: their batch dim is axis 1, not 0."""
    dm = mesh.shape.get("model", 1)

    def one(path, l):
        off = 1 if _is_scan_path(path) else 0
        if l.ndim <= off:
            return NamedSharding(mesh, P())
        spec = [None] * l.ndim
        spec[off] = _fit_batch_axes(mesh, l.shape[off]) or None
        # shard the sequence/time dim over `model` when long and divisible:
        # sequence-parallel KV caches keep 32k decode in HBM.  Short
        # (ring-buffer window) caches stay unsharded — small, and their
        # rotation gathers would hit the partitioner.
        sdim = off + 1
        if (
            l.ndim > sdim
            and l.shape[sdim] % dm == 0
            and l.shape[sdim] >= 8192
            and dm > 1
        ):
            spec[sdim] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def _constrain(tree, shardings):
    return jax.tree.map(
        lambda x, ns: jax.lax.with_sharding_constraint(x, ns), tree, shardings
    )


def _constrain_batch(batch, mesh: Mesh):
    """Pin the batch dim over the (pod, data) device axes inside the step."""

    def one(x):
        if getattr(x, "ndim", 0) == 0:
            return x
        axes = _fit_batch_axes(mesh, x.shape[0])
        if not axes:
            return x
        spec = P(axes, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return jax.tree.map(one, batch)


def _act_constrain(mesh: Mesh, *, seq_parallel: bool = False):
    """Residual-stream constraint at block boundaries.

    Batch over `data` (so GSPMD never resolves an FSDP weight/activation
    conflict by replicating the batch).  ``seq_parallel`` additionally shards
    the sequence dim over `model` (Megatron-style) — measured on this
    container it triggers GSPMD resharding storms under the FSDP weight
    gathers, so it stays off by default.
    """
    dd = mesh.shape.get("data", 1)
    dm = mesh.shape.get("model", 1)
    dp = mesh.shape.get("pod", 1)
    if dd <= 1 and dm <= 1 and dp <= 1:
        return None
    baxes = [a for a in ("pod", "data") if mesh.shape.get(a, 1) > 1]
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]

    def ac(x):
        if x.ndim < 2:
            return x
        spec = [None] * x.ndim
        if baxes and x.shape[0] % bsize == 0:
            spec[0] = tuple(baxes)
        if (
            seq_parallel
            and dm > 1
            and x.ndim >= 3
            and x.shape[1] % dm == 0
        ):
            spec[1] = "model"
        if not any(spec):
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec))
        )

    return ac


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def loss_fn(cfg: ModelConfig, params, batch, compute_dtype=jnp.bfloat16,
            act_constrain=None):
    logits, _ = forward(cfg, params, batch, compute_dtype=compute_dtype,
                        act_constrain=act_constrain)
    labels = batch["labels"]
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0].mean()


# ---------------------------------------------------------------------------
# pod-boundary gradient sync (fully-manual shard_map region)
# ---------------------------------------------------------------------------


def _strip_auto_axes(spec: P) -> P:
    """Drop non-``pod`` mesh axes from a spec.

    Under native partial-auto shard_map (``axis_names={"pod"}`` on modern
    JAX) the in/out specs may only mention the manual axis — ``data`` /
    ``model`` sharding stays with GSPMD.  The fully-manual 0.4.x lowering
    needs the complete specs instead.
    """
    out = []
    for part in spec:
        if part is None:
            out.append(None)
            continue
        parts = part if isinstance(part, tuple) else (part,)
        kept = tuple(a for a in parts if a == "pod")
        out.append(kept[0] if len(kept) == 1 else (kept or None))
    return P(*out)


def _make_pod_sync(mesh: Mesh, tcfg: TrainConfig, p_spec, *, with_residuals: bool):
    """Wrap ``sync_gradients`` in a shard_map over the pod axis.

    Gradients enter at their parameter partitioning (``p_spec``); each
    device holds its FSDP/TP shard and exchanges it across the ``pod`` axis
    under the configured strategy.  Residual state (geococo error feedback)
    is carried at the same partitioning.  On the 0.4.x toolchain the region
    is fully manual (complete specs); on a native partial-auto JAX only the
    pod components survive in the specs.
    """
    n_pods = mesh.shape.get("pod", 1)
    if compat.has_partial_auto():
        p_spec = jax.tree.map(_strip_auto_axes, p_spec)

    if with_residuals:

        def body(g, r):
            return sync_gradients(g, r, tcfg.sync, axis="pod", n_pods=n_pods)

        return compat.shard_map(
            body, mesh,
            in_specs=(p_spec, p_spec), out_specs=(p_spec, p_spec),
            axis_names={"pod"},
        )

    def body(g):
        return sync_gradients(g, None, tcfg.sync, axis="pod", n_pods=n_pods)[0]

    return compat.shard_map(
        body, mesh, in_specs=(p_spec,), out_specs=p_spec, axis_names={"pod"},
    )


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig):
    """Returns (make_jit, shardings dict).

    step(params, opt_state, residuals, batch) ->
        (params', opt_state', residuals', metrics)
    """
    n_pods = mesh.shape.get("pod", 1)
    p_abs = abstract_params(cfg, tcfg.param_dtype)
    p_spec = param_specs(p_abs, mesh, tcfg.sync.strategy)
    p_shard = param_shardings(p_abs, mesh, tcfg.sync.strategy)
    opt_shard = {
        "m": p_shard,
        "v": p_shard,
        "step": NamedSharding(mesh, P()),
    }
    res_abs = abstract_residuals(cfg, tcfg)
    res_shard = p_shard if res_abs is not None else None

    ac = _act_constrain(mesh) if tcfg.sync.strategy != "flat" else None
    n_micro = max(1, tcfg.microbatches)
    pod_sync = (
        _make_pod_sync(mesh, tcfg, p_spec,
                       with_residuals=res_abs is not None)
        if n_pods > 1
        else None
    )

    def core(params, opt_state, residuals, batch):
        from ..dist import context as dist_context

        params = _constrain(params, p_shard)
        with dist_context.distribution(mesh):
            if n_micro == 1:
                b = _constrain_batch(batch, mesh)
                loss, grads = jax.value_and_grad(
                    lambda p: loss_fn(cfg, p, b, tcfg.compute_dtype, ac)
                )(params)
            else:
                # gradient accumulation: one fwd/bwd per microbatch; only the
                # accumulated gradient crosses the pod boundary (per-step sync
                # frequency unchanged — the paper's epoch semantics)
                micro = jax.tree.map(
                    lambda x: x.reshape(
                        (n_micro, x.shape[0] // n_micro) + x.shape[1:]
                    ),
                    batch,
                )
                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )

                def mb_step(carry, mbatch):
                    gsum, lsum = carry
                    b = _constrain_batch(mbatch, mesh)
                    l, g = jax.value_and_grad(
                        lambda p: loss_fn(cfg, p, b, tcfg.compute_dtype, ac)
                    )(params)
                    gsum = jax.tree.map(
                        lambda a, x: a + x.astype(jnp.float32), gsum, g
                    )
                    return (gsum, lsum + l), None

                (gsum, lsum), _ = jax.lax.scan(
                    mb_step, (g0, jnp.zeros((), jnp.float32)), micro
                )
                grads = jax.tree.map(
                    lambda g, p: (g / n_micro).astype(p.dtype), gsum, params
                )
                loss = lsum / n_micro
        new_res = residuals
        if pod_sync is not None:
            if res_abs is not None:
                grads, new_res = pod_sync(grads, residuals)
            else:
                grads = pod_sync(grads)
        new_params, new_opt, metrics = adamw_update(
            params, grads, opt_state, tcfg.optim
        )
        new_params = _constrain(new_params, p_shard)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, new_res, metrics

    def make_jit(batch_tree):
        b_shard = _batch_shardings(batch_tree, mesh)
        in_sh = (p_shard, opt_shard, res_shard, b_shard)
        out_sh = (p_shard, opt_shard, res_shard, None)
        return jax.jit(
            core,
            in_shardings=in_sh,
            out_shardings=out_sh,
            donate_argnums=(0, 1, 2),
        )

    shardings = {"params": p_shard, "opt": opt_shard, "residuals": res_shard}
    return make_jit, shardings


def build_serve_step(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig,
                     *, kind: str = "decode"):
    """Prefill: step(params, batch) -> logits.
    Decode: step(params, cache, batch) -> (next_tokens, new_cache)."""
    p_abs = abstract_params(cfg, tcfg.param_dtype)
    p_shard = param_shardings(p_abs, mesh, tcfg.sync.strategy)

    if kind == "prefill":
        ac = _act_constrain(mesh) if tcfg.sync.strategy != "flat" else None

        def core(params, batch):
            from ..dist import context as dist_context

            params = _constrain(params, p_shard)
            batch = _constrain_batch(batch, mesh)
            with dist_context.distribution(mesh):
                logits, _ = forward(cfg, params, batch,
                                    compute_dtype=tcfg.compute_dtype,
                                    act_constrain=ac)
            return logits

        def make_jit(batch_tree):
            b_shard = _batch_shardings(batch_tree, mesh)
            return jax.jit(core, in_shardings=(p_shard, b_shard))

        return make_jit, {"params": p_shard}

    ac_dec = _act_constrain(mesh) if tcfg.sync.strategy != "flat" else None

    def core(params, cache, batch):
        from ..dist import context as dist_context

        params = _constrain(params, p_shard)
        batch = _constrain_batch(batch, mesh)
        cache = _constrain(cache, _cache_shardings(cache, mesh))
        with dist_context.distribution(mesh):
            logits, new_cache = forward(
                cfg, params, batch, cache=cache,
                compute_dtype=tcfg.compute_dtype,
                act_constrain=ac_dec,
            )
        next_tok = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return next_tok.astype(jnp.int32), new_cache

    def make_jit(cache_tree, batch_tree):
        c_shard = _cache_shardings(cache_tree, mesh)
        b_shard = _batch_shardings(batch_tree, mesh)
        gb = next(iter(jax.tree.leaves(batch_tree))).shape[0]
        tok_shard = NamedSharding(mesh, P(_fit_batch_axes(mesh, gb) or None))
        return jax.jit(
            core,
            in_shardings=(p_shard, c_shard, b_shard),
            out_shardings=(tok_shard, c_shard),
            donate_argnums=(1,),
        )

    return make_jit, {"params": p_shard}
