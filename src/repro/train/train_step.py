"""Train / serve step builders: model + sync strategy + optimizer, sharded.

The step is built once per (arch, shape, mesh, strategy) cell:

* single-pod mesh — plain ``jax.jit`` with GSPMD (FSDP+TP in-pod).
* multi-pod mesh — partial-manual ``jax.shard_map`` over the `pod` axis:
  GSPMD still owns `data`/`model` inside, while the pod boundary runs the
  GeoCoCo communicator (``repro.dist.collectives``) explicitly.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input, so
the multi-pod dry-run lowers and compiles with zero allocation.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeSpec
from ..dist.collectives import SyncConfig, sync_gradients
from ..dist.sharding import param_shardings, param_specs
from ..models.model import forward, init_cache, init_params
from ..optim.adamw import AdamWConfig, adamw_init, adamw_update

__all__ = [
    "TrainConfig",
    "input_specs",
    "abstract_params",
    "abstract_opt_state",
    "abstract_residuals",
    "abstract_cache",
    "build_train_step",
    "build_serve_step",
    "loss_fn",
]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    sync: SyncConfig = SyncConfig()
    optim: AdamWConfig = AdamWConfig()
    param_dtype: Any = jnp.float32      # bf16 for the lean 671B policy
    compute_dtype: Any = jnp.bfloat16
    # gradient-accumulation microbatches: activation memory scales ~1/m and
    # gradients sync once per step (GeoCoCo semantics unchanged)
    microbatches: int = 1


# ---------------------------------------------------------------------------
# abstract inputs (dry-run stand-ins)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for the model inputs of one cell."""
    gb, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        toks = {"tokens": jax.ShapeDtypeStruct((gb, 1), jnp.int32)}
        if cfg.n_img_tokens:
            toks["img"] = jax.ShapeDtypeStruct(
                (gb, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16
            )
        return toks
    batch: dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.frontend == "token":
        batch["tokens"] = jax.ShapeDtypeStruct((gb, s), jnp.int32)
    else:
        batch["embeds"] = jax.ShapeDtypeStruct((gb, s, cfg.d_model), jnp.bfloat16)
    if cfg.n_img_tokens:
        batch["img"] = jax.ShapeDtypeStruct(
            (gb, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16
        )
    if shape.kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((gb, s), jnp.int32)
    return batch


def abstract_params(cfg: ModelConfig, dtype=jnp.float32):
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, dtype), shapes)


def abstract_opt_state(cfg: ModelConfig, tcfg: TrainConfig):
    params = abstract_params(cfg, tcfg.param_dtype)
    return jax.eval_shape(lambda p: adamw_init(p, tcfg.optim), params)


def abstract_residuals(cfg: ModelConfig, tcfg: TrainConfig):
    if tcfg.sync.strategy != "geococo":
        return None
    params = abstract_params(cfg, tcfg.param_dtype)
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32), params)


def abstract_cache(cfg: ModelConfig, shape: ShapeSpec, dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len, dtype)
    )


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------


def _fit_batch_axes(mesh: Mesh, dim: int) -> tuple[str, ...]:
    """Largest prefix-combination of (pod, data) that divides ``dim``.

    A global_batch of 1 (long_500k single-request decode) replicates over the
    batch axes; the model axis still shards the compute."""
    cands = [("pod", "data"), ("data",), ("pod",)]
    for axes in cands:
        if all(a in mesh.shape for a in axes):
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if size > 1 and dim % size == 0:
                return axes
    return ()


def _batch_shardings(batch_tree, mesh: Mesh):
    def one(l):
        if getattr(l, "ndim", 0) == 0:
            return NamedSharding(mesh, P())
        axes = _fit_batch_axes(mesh, l.shape[0])
        return NamedSharding(mesh, P(axes or None, *([None] * (l.ndim - 1))))

    return jax.tree.map(one, batch_tree)


def _is_scan_path(path) -> bool:
    for p in path:
        if getattr(p, "key", None) == "scan":
            return True
    return False


def _cache_shardings(cache_tree, mesh: Mesh):
    """Decode-cache shardings.  Leaves under the "scan" key are stacked with
    a leading super-block axis: their batch dim is axis 1, not 0."""
    dm = mesh.shape.get("model", 1)

    def one(path, l):
        off = 1 if _is_scan_path(path) else 0
        if l.ndim <= off:
            return NamedSharding(mesh, P())
        spec = [None] * l.ndim
        spec[off] = _fit_batch_axes(mesh, l.shape[off]) or None
        # shard the sequence/time dim over `model` when long and divisible:
        # sequence-parallel KV caches keep 32k decode in HBM.  Short
        # (ring-buffer window) caches stay unsharded — small, and their
        # rotation gathers would hit the partitioner.
        sdim = off + 1
        if (
            l.ndim > sdim
            and l.shape[sdim] % dm == 0
            and l.shape[sdim] >= 8192
            and dm > 1
        ):
            spec[sdim] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def _strip_pod(ns: NamedSharding) -> P:
    """Drop the manual `pod` axis from a spec (for inner GSPMD constraints)."""
    out = []
    for part in ns.spec:
        if part is None:
            out.append(None)
            continue
        parts = part if isinstance(part, tuple) else (part,)
        kept = tuple(a for a in parts if a != "pod")
        out.append(kept[0] if len(kept) == 1 else (kept or None))
    return P(*out)


def _under_manual_mesh() -> bool:
    ctx = jax.sharding.get_abstract_mesh()
    return ctx is not None and bool(ctx.axis_names)


def _inner_constrain(tree, shardings):
    """Apply GSPMD constraints for auto axes.

    Inside the manual-pod region PartitionSpecs are required (the context
    mesh supplies the axes); in a plain jit (single-pod) the NamedSharding
    itself is used — with_sharding_constraint rejects bare specs there."""
    if _under_manual_mesh():
        return jax.tree.map(
            lambda x, ns: jax.lax.with_sharding_constraint(x, _strip_pod(ns)),
            tree,
            shardings,
        )
    return jax.tree.map(
        lambda x, ns: jax.lax.with_sharding_constraint(x, ns), tree, shardings
    )


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def _constrain_batch(batch, mesh: Mesh):
    """Pin the batch dim to the `data` axis inside the manual-pod region
    (the pod part of the sharding is consumed by shard_map's in_specs)."""
    if mesh.shape.get("data", 1) <= 1:
        return batch

    def one(x):
        if getattr(x, "ndim", 0) == 0 or x.shape[0] % mesh.shape["data"]:
            return x
        spec = P(*(["data"] + [None] * (x.ndim - 1)))
        if _under_manual_mesh():
            return jax.lax.with_sharding_constraint(x, spec)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return jax.tree.map(one, batch)


def _act_constrain(mesh: Mesh, *, seq_parallel: bool = False):
    """Residual-stream constraint at block boundaries.

    Batch over `data` (so GSPMD never resolves an FSDP weight/activation
    conflict by replicating the batch).  ``seq_parallel`` additionally shards
    the sequence dim over `model` (Megatron-style) — measured on this
    container it triggers GSPMD resharding storms under the FSDP weight
    gathers (data-axis collectives x14, +27% FLOPs; EXPERIMENTS.md §Perf,
    refuted hypothesis), so it stays off by default.
    """
    dd = mesh.shape.get("data", 1)
    dm = mesh.shape.get("model", 1)
    if dd <= 1 and dm <= 1:
        return None

    def ac(x):
        if x.ndim < 2:
            return x
        spec = [None] * x.ndim
        if dd > 1 and x.shape[0] % dd == 0:
            spec[0] = "data"
        if (
            seq_parallel
            and dm > 1
            and x.ndim >= 3
            and x.shape[1] % dm == 0
        ):
            spec[1] = "model"
        if not any(spec):
            return x
        pspec = P(*spec)
        if _under_manual_mesh():
            return jax.lax.with_sharding_constraint(x, pspec)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))

    return ac


def _make_embed_fn(mesh: Mesh):
    """Explicitly-sharded vocab lookup via a fully-manual nested shard_map.

    XLA's SPMD gather partitioner CHECK-fails on CPU when asked to evaluate
    sharded-gather strategies under a manual pod axis (spmd_partitioner_util
    ExpandDeviceGroupsWithIota), so the lookup is expressed manually: the
    table enters replicated-over-`data` / TP-sharded-over-`model` on d_model,
    tokens enter batch-sharded over `data`; each device gathers its local
    (vocab, d/TP) shard.  The transpose rule then inserts the correct psum
    over `data` for the table gradient automatically.
    """
    manual = tuple(a for a in ("data", "model") if mesh.shape.get(a, 1) > 1)
    if not manual:
        return None
    has_d = "data" in manual
    has_m = "model" in manual

    def embed_fn(embed_params, tokens, dtype):
        # boundary in f32: the table cotangent psums over `data`, and bf16
        # all-reduces CHECK-fail in XLA's CPU promotion pass
        table = embed_params["table"].astype(jnp.float32)
        tspec = P(None, "model" if has_m and table.shape[1] % mesh.shape["model"] == 0 else None)
        kspec = P("data" if has_d and tokens.shape[0] % mesh.shape["data"] == 0 else None)
        ospec = P(kspec[0], None, tspec[1])

        def local(tbl, tok):
            return tbl.astype(dtype)[tok]

        # inside the manual-pod region the context mesh (with `pod` marked
        # Manual) must be used; outside it the concrete mesh works
        ctx = jax.sharding.get_abstract_mesh()
        use_mesh = ctx if (ctx is not None and ctx.axis_names) else mesh
        return jax.shard_map(
            local, mesh=use_mesh,
            in_specs=(tspec, kspec), out_specs=ospec,
            axis_names=set(manual), check_vma=False,
        )(table, tokens)

    return embed_fn


def _sharded_xent(mesh: Mesh, logits, labels):
    """Cross-entropy over vocab-sharded logits via manual collectives.

    The logits arrive (B over data, S, V over model).  Each device computes
    a local logsumexp contribution and its local slice's label logit; psum
    over `model` assembles both.  This avoids (a) materializing a full fp32
    log_softmax and (b) XLA's scatter partitioner in the take_along_axis
    backward (CHECK-fails on CPU under a manual pod axis).
    """
    manual = tuple(a for a in ("data", "model") if mesh.shape.get(a, 1) > 1)
    dm = mesh.shape.get("model", 1)
    if not manual or logits.shape[-1] % dm or dm <= 1:
        lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        return -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0].mean()

    has_d = "data" in manual and logits.shape[0] % mesh.shape["data"] == 0
    bspec = "data" if has_d else None
    # per-shard vocab offsets delivered as a model-sharded iota (avoids
    # axis_index, whose lowering re-binds the outer manual pod axis)
    offsets = jnp.arange(dm, dtype=jnp.int32) * (logits.shape[-1] // dm)

    def local(lg, lb, off):
        lg = lg.astype(jnp.float32)
        vl = lg.shape[-1]
        lo = off[0]
        # stability max carries no gradient (logsumexp is shift-invariant);
        # stop_gradient must wrap the operand — pmax has no JVP rule
        m = jax.lax.pmax(jax.lax.stop_gradient(jnp.max(lg, axis=-1)), "model")
        se = jnp.sum(jnp.exp(lg - m[..., None]), axis=-1)
        lse = jnp.log(jax.lax.psum(se, "model")) + m
        idx = lb - lo
        ok = (idx >= 0) & (idx < vl)
        ll = jnp.take_along_axis(lg, jnp.clip(idx, 0, vl - 1)[..., None], -1)[..., 0]
        ll = jax.lax.psum(jnp.where(ok, ll, 0.0), "model")
        loss = (lse - ll).mean()
        if has_d:
            loss = jax.lax.pmean(loss, "data")
        return loss

    ctx_mesh = jax.sharding.get_abstract_mesh()
    use_mesh = ctx_mesh if (ctx_mesh is not None and ctx_mesh.axis_names) else mesh
    return jax.shard_map(
        local, mesh=use_mesh,
        in_specs=(P(bspec, None, "model"), P(bspec), P("model")),
        out_specs=P(),
        axis_names=set(manual), check_vma=False,
    )(logits, labels, offsets)


def loss_fn(cfg: ModelConfig, params, batch, compute_dtype=jnp.bfloat16,
            act_constrain=None, embed_fn=None, mesh: Mesh | None = None):
    logits, _ = forward(cfg, params, batch, compute_dtype=compute_dtype,
                        act_constrain=act_constrain, embed_fn=embed_fn)
    labels = batch["labels"]
    if mesh is not None:
        return _sharded_xent(mesh, logits, labels)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0].mean()


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig):
    """Returns (jitted_step, shardings dict).

    step(params, opt_state, residuals, batch) ->
        (params', opt_state', residuals', metrics)
    """
    n_pods = mesh.shape.get("pod", 1)
    p_shard = param_shardings(abstract_params(cfg, tcfg.param_dtype), mesh,
                              tcfg.sync.strategy)
    opt_shard = {
        "m": p_shard,
        "v": p_shard,
        "step": NamedSharding(mesh, P()),
    }
    res_abs = abstract_residuals(cfg, tcfg)
    res_shard = p_shard if res_abs is not None else None

    ac = _act_constrain(mesh) if tcfg.sync.strategy != "flat" else None
    emb = _make_embed_fn(mesh)
    leaf_specs = jax.tree.map(_strip_pod, p_shard)

    n_micro = max(1, tcfg.microbatches)

    def core(params, opt_state, residuals, batch):
        from ..dist import context as dist_context

        params = _inner_constrain(params, p_shard)
        with dist_context.distribution(mesh):
            if n_micro == 1:
                b = _constrain_batch(batch, mesh)
                loss, grads = jax.value_and_grad(
                    lambda p: loss_fn(cfg, p, b, tcfg.compute_dtype, ac, emb,
                                      mesh)
                )(params)
            else:
                # gradient accumulation: one fwd/bwd per microbatch; only the
                # accumulated gradient crosses the pod boundary (per-step sync
                # frequency unchanged — the paper's epoch semantics)
                micro = jax.tree.map(
                    lambda x: x.reshape(
                        (n_micro, x.shape[0] // n_micro) + x.shape[1:]
                    ),
                    batch,
                )
                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )

                def mb_step(carry, mbatch):
                    gsum, lsum = carry
                    b = _constrain_batch(mbatch, mesh)
                    l, g = jax.value_and_grad(
                        lambda p: loss_fn(cfg, p, b, tcfg.compute_dtype, ac,
                                          emb, mesh)
                    )(params)
                    gsum = jax.tree.map(
                        lambda a, x: a + x.astype(jnp.float32), gsum, g
                    )
                    return (gsum, lsum + l), None

                (gsum, lsum), _ = jax.lax.scan(
                    mb_step, (g0, jnp.zeros((), jnp.float32)), micro
                )
                grads = jax.tree.map(
                    lambda g, p: (g / n_micro).astype(p.dtype), gsum, params
                )
                loss = lsum / n_micro
        grads, new_res = sync_gradients(
            grads, residuals, tcfg.sync, axis="pod", n_pods=n_pods,
            leaf_specs=leaf_specs,
        )
        loss = jax.lax.pmean(loss, "pod") if n_pods > 1 else loss
        new_params, new_opt, metrics = adamw_update(
            params, grads, opt_state, tcfg.optim
        )
        new_params = _inner_constrain(new_params, p_shard)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, new_res, metrics

    if n_pods > 1:
        stepped = jax.shard_map(
            core,
            mesh=mesh,
            in_specs=(P(), P(), P(), P("pod")),
            out_specs=(P(), P(), P(), P()),
            axis_names={"pod"},
            check_vma=False,
        )
    else:
        stepped = core

    def make_jit(batch_tree):
        b_shard = _batch_shardings(batch_tree, mesh)
        in_sh = (p_shard, opt_shard, res_shard, b_shard)
        out_sh = (p_shard, opt_shard, res_shard, None)
        return jax.jit(
            stepped,
            in_shardings=in_sh,
            out_shardings=out_sh,
            donate_argnums=(0, 1, 2),
        )

    shardings = {"params": p_shard, "opt": opt_shard, "residuals": res_shard}
    return make_jit, shardings


def build_serve_step(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig,
                     *, kind: str = "decode"):
    """Prefill: step(params, batch) -> logits.
    Decode: step(params, cache, batch) -> (next_tokens, new_cache)."""
    p_shard = param_shardings(abstract_params(cfg, tcfg.param_dtype), mesh,
                              tcfg.sync.strategy)
    n_pods = mesh.shape.get("pod", 1)

    if kind == "prefill":
        ac = _act_constrain(mesh) if tcfg.sync.strategy != "flat" else None
        emb = _make_embed_fn(mesh)

        def core(params, batch):
            from ..dist import context as dist_context

            params = _inner_constrain(params, p_shard)
            batch = _constrain_batch(batch, mesh)
            with dist_context.distribution(mesh):
                logits, _ = forward(cfg, params, batch,
                                    compute_dtype=tcfg.compute_dtype,
                                    act_constrain=ac, embed_fn=emb)
            return logits

        if n_pods > 1:
            core_sm = jax.shard_map(
                core, mesh=mesh,
                in_specs=(P(), P("pod")), out_specs=P("pod"),
                axis_names={"pod"}, check_vma=False,
            )
        else:
            core_sm = core

        def make_jit(batch_tree):
            b_shard = _batch_shardings(batch_tree, mesh)
            return jax.jit(core_sm, in_shardings=(p_shard, b_shard))

        return make_jit, {"params": p_shard}

    ac_dec = _act_constrain(mesh) if tcfg.sync.strategy != "flat" else None
    emb_dec = _make_embed_fn(mesh)

    def core(params, cache, batch):
        from ..dist import context as dist_context

        params = _inner_constrain(params, p_shard)
        batch = _constrain_batch(batch, mesh)
        cache = _inner_constrain(cache, _cache_shardings(cache, mesh))
        with dist_context.distribution(mesh):
            logits, new_cache = forward(
                cfg, params, batch, cache=cache,
                compute_dtype=tcfg.compute_dtype,
                act_constrain=ac_dec, embed_fn=emb_dec,
            )
        next_tok = jnp.argmax(logits[:, -1].astype(jnp.float32), axis=-1)
        return next_tok.astype(jnp.int32), new_cache

    def make_jit(cache_tree, batch_tree):
        def pod_spec(path, l):
            off = 1 if _is_scan_path(path) else 0
            if getattr(l, "ndim", 0) <= off:
                return P()
            if l.shape[off] % n_pods:
                return P()
            return P(*([None] * off + ["pod"]))

        if n_pods > 1:
            cache_spec = jax.tree_util.tree_map_with_path(pod_spec, cache_tree)
            batch_spec = jax.tree_util.tree_map_with_path(pod_spec, batch_tree)
            gb = next(iter(jax.tree.leaves(batch_tree))).shape[0]
            tok_spec = P("pod") if gb % n_pods == 0 else P()
            core_sm = jax.shard_map(
                core, mesh=mesh,
                in_specs=(P(), cache_spec, batch_spec),
                out_specs=(tok_spec, cache_spec),
                axis_names={"pod"}, check_vma=False,
            )
        else:
            core_sm = core
        c_shard = _cache_shardings(cache_tree, mesh)
        b_shard = _batch_shardings(batch_tree, mesh)
        gb = next(iter(jax.tree.leaves(batch_tree))).shape[0]
        tok_shard = NamedSharding(mesh, P(_fit_batch_axes(mesh, gb) or None))
        return jax.jit(
            core_sm,
            in_shardings=(p_shard, c_shard, b_shard),
            out_shardings=(tok_shard, c_shard),
            donate_argnums=(1,),
        )

    return make_jit, {"params": p_shard}
