"""GeoCoCo quickstart: the paper's pipeline end to end in ~40 lines of API.

    PYTHONPATH=src python examples/quickstart.py

1. Build a geo-clustered WAN and monitor it.
2. Plan latency-aware groups (MILP) with TIV-aware relays.
3. Synchronize one epoch hierarchically with white-data filtering.
4. Compare makespan / WAN bytes / consistency against flat all-to-all.
"""

import numpy as np

from repro.core import (
    EngineConfig,
    GeoCluster,
    GeoClusterSpec,
    WANSimulator,
    YCSBConfig,
    YCSBGenerator,
    all_to_all_schedule,
    best_plan,
    geo_clustered_matrix,
    hierarchical_schedule,
    jitter_trace,
    tiv_fraction,
)


def main():
    rng = np.random.default_rng(0)
    n = 9
    lat, regions = geo_clustered_matrix(
        GeoClusterSpec(n_nodes=n, n_clusters=3, congestion_frac=0.35), rng
    )
    print(f"{n}-node WAN over 3 regions; "
          f"{tiv_fraction(lat):.0%} of pairs violate the triangle inequality")

    # LAN >> WAN bandwidth asymmetry (paper Sec 2.2)
    same = regions[:, None] == regions[None, :]
    bw = np.where(same, 10_000.0, 150.0).astype(float)
    np.fill_diagonal(bw, np.inf)

    # --- Planner: latency-aware grouping (paper Sec 4.2) -------------------
    plan = best_plan(lat, tiv=True, method="milp",
                     payload_bytes=100_000.0, bandwidth_mbps=bw)
    print(f"plan: k={plan.k} groups {plan.groups} aggregators {plan.aggregators}"
          f"  (objective {plan.objective:.1f} ms, solved in {plan.solve_time_s*1e3:.0f} ms)")

    # --- Communicator: one round, flat vs hierarchical ---------------------
    sim = WANSimulator(lat, bandwidth_mbps=bw)
    m_flat = sim.run(all_to_all_schedule(n, 100_000.0)).makespan_ms
    m_geo = sim.run(
        hierarchical_schedule(plan, 100_000.0, lat=lat, tiv=True)
    ).makespan_ms
    print(f"single-round makespan: flat {m_flat:.0f} ms -> geococo {m_geo:.0f} ms"
          f"  ({1 - m_geo / m_flat:+.0%})")

    # --- Full engine: epochs with OCC + CRDT + filtering --------------------
    trace = jitter_trace(lat, 30, np.random.default_rng(1))
    results = {}
    for name, (grp, filt) in {"flat": (False, False),
                              "geococo": (True, True)}.items():
        eng = GeoCluster(
            EngineConfig(n_nodes=n, grouping=grp, filtering=filt, tiv=True,
                         planner="kcenter"),
            bandwidth_mbps=bw, wan_mask=~same, seed=2,
        )
        gen = YCSBGenerator(
            YCSBConfig(n_keys=5000, theta=0.8, read_ratio=0.5,
                       hot_write_frac=0.3, hot_locality=True),
            n, seed=3, node_region=regions,
        )
        results[name] = eng.run(gen, trace, txns_per_node=10)

    a, b = results["flat"], results["geococo"]
    print(f"30 epochs: throughput {a.throughput_tps:.0f} -> {b.throughput_tps:.0f} tps"
          f" ({b.throughput_tps / a.throughput_tps - 1:+.0%}),"
          f" WAN bytes {a.wan_bytes/1e6:.1f} -> {b.wan_bytes/1e6:.1f} MB"
          f" ({1 - b.wan_bytes / a.wan_bytes:+.0%} saved),"
          f" white-data ratio {b.white_stats.white_byte_ratio:.0%}")
    assert a.state_digest == b.state_digest
    print("final replicated state identical across modes — filtering is lossless")


if __name__ == "__main__":
    main()
