"""Geo-distributed database simulation: the paper's evaluation in miniature.

    PYTHONPATH=src python examples/geo_database_sim.py

Replays the paper's 5-node real-world testbed (2 Kalgan + 2 Hohhot +
1 Hong Kong) under TPC-C and YCSB workloads, comparing the default flat
synchronization against GeoCoCo (grouping + TIV relays + white-data
filtering), with an aggregator failure injected mid-run.
"""

import numpy as np

from repro.core import (
    EngineConfig,
    GeoCluster,
    TPCCConfig,
    TPCCGenerator,
    YCSBConfig,
    YCSBGenerator,
    jitter_trace,
)


def paper_testbed(n_rounds: int, seed: int = 0):
    base = np.array(
        [
            [0.0, 1.5, 8.0, 8.5, 42.0],
            [1.5, 0.0, 8.2, 8.0, 43.0],
            [8.0, 8.2, 0.0, 1.8, 38.0],
            [8.5, 8.0, 1.8, 0.0, 39.0],
            [42.0, 43.0, 38.0, 39.0, 0.0],
        ]
    )
    regions = np.array([0, 0, 1, 1, 2])
    return base, regions, jitter_trace(base, n_rounds, np.random.default_rng(seed))


def main():
    n, epochs = 5, 60
    base, regions, trace = paper_testbed(epochs)
    print("testbed: Kalgan x2, Hohhot x2, Hong Kong x1 (paper Sec 6.1)\n")

    print("== TPC-C (100 warehouses) ==")
    for mix in ("TPCC-A", "TPCC-B", "TPCC-C", "TPCC-D"):
        rows = {}
        for name, grp in (("GeoGauss", False), ("+GeoCoCo", True)):
            eng = GeoCluster(
                EngineConfig(n_nodes=n, grouping=grp, filtering=grp, tiv=grp,
                             planner="milp"),
                bandwidth_mbps=120.0, seed=3,
            )
            gen = TPCCGenerator(TPCCConfig(n_warehouses=100, mix=mix), n, seed=3)
            rows[name] = eng.run(gen, trace, txns_per_node=12)
        a, b = rows["GeoGauss"], rows["+GeoCoCo"]
        print(f"  {mix}: tpmTotal {a.throughput_tps*60:,.0f} -> {b.throughput_tps*60:,.0f}"
              f"  ({b.throughput_tps/a.throughput_tps-1:+.1%}); "
              f"state identical: {a.state_digest == b.state_digest}")

    print("\n== YCSB (theta=0.8, 50/50) with aggregator failover ==")
    eng = GeoCluster(
        EngineConfig(n_nodes=n, grouping=True, filtering=True, tiv=True,
                     planner="milp"),
        bandwidth_mbps=120.0, seed=5,
    )
    gen = YCSBGenerator(
        YCSBConfig(n_keys=10_000, theta=0.8, read_ratio=0.5,
                   hot_write_frac=0.3, hot_locality=True),
        n, seed=5, node_region=regions,
    )
    # run half, fail the current aggregator of group 0, run the rest;
    # the failure flows through the network control plane as a typed
    # PlanChanged event every subscriber (any plane) observes
    half = epochs // 2
    rs1 = eng.run(gen, trace, txns_per_node=12, n_epochs=half)
    plan = eng.control.plan
    victim = plan.aggregators[0]
    eng.control.on_node_failure(victim)
    print(f"  injected failure of aggregator node {victim} at epoch {half}; "
          "members fall back + replan next round")
    rs2 = eng.run(gen, trace, txns_per_node=12, n_epochs=half)
    print(f"  committed {rs1.committed}+{rs2.committed} txns; "
          f"white-data filtered {rs2.white_stats.white_byte_ratio:.0%} of bytes; "
          f"replans: {eng.control.replan_count}; "
          f"control events: {eng.control.event_counts()}")
    print("  run completed with consistent state "
          f"(digest {eng.store.digest()[:12]}...)")


if __name__ == "__main__":
    main()
