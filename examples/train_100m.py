"""End-to-end driver: train a ~100M-parameter model with GeoCoCo sync.

    PYTHONPATH=src python examples/train_100m.py                  # ~100M, 300 steps
    PYTHONPATH=src python examples/train_100m.py --small --steps 40   # CI-sized

Runs on 8 forced host devices arranged as a (2, 2, 2) = (pod, data, model)
mesh: FSDP+TP inside each pod (GSPMD) and GeoCoCo's filtered top-k exchange
across the pod (WAN-analogue) boundary, with periodic checkpointing.
"""

import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true",
                    help="~20M params / short seq for CI")
    ap.add_argument("--sync", default="geococo",
                    choices=["flat", "hier", "geococo"])
    ap.add_argument("--ckpt-dir", default="/tmp/geococo_train_100m")
    args = ap.parse_args()

    import dataclasses

    import jax

    from repro.configs.base import Block, ModelConfig
    from repro.data.pipeline import DataConfig
    from repro.dist.collectives import SyncConfig
    from repro.launch.mesh import make_small_mesh
    from repro.models.model import param_count
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import TrainConfig
    from repro.train.trainer import Trainer, TrainerConfig

    if args.small:
        cfg = ModelConfig(
            name="demo-20m", family="dense", n_layers=4, d_model=256,
            n_heads=8, n_kv_heads=4, d_ff=1024, vocab_size=32_000,
            blocks_pattern=(Block("attn", "dense"),),
        )
        seq, gb = 128, 8
    else:
        # ~100M-parameter llama-style model
        cfg = ModelConfig(
            name="demo-100m", family="dense", n_layers=8, d_model=640,
            n_heads=10, n_kv_heads=5, d_ff=2560, vocab_size=32_000,
            blocks_pattern=(Block("attn", "dense"),),
        )
        seq, gb = 256, 8

    print(f"model {cfg.name}: {param_count(cfg)/1e6:.1f}M params; "
          f"devices {jax.device_count()}, sync={args.sync}")
    mesh = make_small_mesh()
    tcfg = TrainConfig(
        sync=SyncConfig(strategy=args.sync, density=0.10, chunk=2048,
                        min_leaf_size=16_384),
        optim=AdamWConfig(lr=6e-4, total_steps=args.steps, warmup_steps=20),
    )
    run_cfg = TrainerConfig(
        steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=100,
        log_every=10, seed=0,
    )
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                          global_batch=gb, seed=0)
    trainer = Trainer(cfg, mesh, tcfg, run_cfg, data_cfg)
    if trainer.maybe_resume():
        print(f"resumed from checkpoint at step {trainer.step_idx}")
    hist = trainer.run()
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"\nloss {first:.4f} -> {last:.4f} over {len(hist)} steps "
          f"({(1 - last / first):+.1%})")
    assert last < first, "training must reduce the loss"


if __name__ == "__main__":
    main()
