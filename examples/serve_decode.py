"""Batched serving example: prefill + decode with KV caches on a mesh.

    PYTHONPATH=src python examples/serve_decode.py [--arch rwkv6-7b-smoke]

Serves a reduced-config model on 8 forced host devices: batch prefill of
mixed prompts, then greedy decode steps, exercising the serve path the
decode_32k / long_500k dry-run cells compile at full scale (KV/ring/state
caches included).
"""

import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-8b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.registry import get_smoke_config
    from repro.launch.mesh import make_small_mesh
    from repro.models.model import forward, init_cache, init_params
    from repro.train.train_step import TrainConfig, build_serve_step

    cfg = get_smoke_config(args.arch)
    mesh = make_small_mesh()
    tcfg = TrainConfig()
    rng = np.random.default_rng(0)

    params = init_params(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.gen_len
    cache = init_cache(cfg, args.batch, max_len, dtype=jnp.float32)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32
    )

    # prefill (uses the cached forward so decode can continue)
    logits, cache = forward(cfg, params, {"tokens": prompts}, cache=cache,
                            compute_dtype=jnp.float32)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    print(f"arch={cfg.name}: prefilled {args.batch} x {args.prompt_len} tokens")

    # jitted decode step on the mesh
    make_jit, _ = build_serve_step(cfg, mesh, tcfg, kind="decode")
    batch0 = {"tokens": tok[:, None]}
    if cfg.n_img_tokens:
        batch0["img"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.n_img_tokens, cfg.d_model)),
            jnp.float32,
        )
    step = make_jit(jax.tree.map(lambda x: x, cache), batch0)

    outs = [tok]
    for t in range(args.gen_len - 1):
        batch_t = dict(batch0, tokens=outs[-1][:, None])
        tok, cache = step(params, cache, batch_t)
        outs.append(tok)
    gen = np.stack([np.asarray(t) for t in outs], axis=1)
    print(f"decoded {gen.shape[1]} steps; sample row: {gen[0].tolist()}")
    assert np.isfinite(gen).all()
    print("serving path OK (prefill -> jitted sharded decode with cache)")


if __name__ == "__main__":
    main()
